"""Dynamic-graph serving: incremental maintenance vs rebuild-from-scratch.

The paper's §1 motivation made quantitative.  ProbeSim is index-free, so an
edge update is an O(1) buffer write into the capacity-padded COO/ELL
mirrors (owned by one ``GraphHandle``) and the next query is already exact
w.r.t. the new graph; index-based competitors must rebuild before the
first fresh query (TSF: the R_g one-way graphs; SLING: the whole index).
Two measurements against a rebuild-from-scratch baseline (rebuild the
handle from the updated host edge list — the cheapest possible "index",
i.e. a lower bound on any index-based competitor's maintenance cost):

* **sustained update throughput** (edges/sec): rounds of fixed-size update
  batches through the jitted coordinated apply (``GraphHandle.apply_batch``,
  both mirrors, on device) vs a host rebuild of the handle per batch;
* **update->queryable latency** (seconds): time from an update batch's
  arrival until the post-update graph state is resident and consistent on
  device, ready for the next fused query dispatch — the freshness gap a
  query observes.  For context we also report the fused epoch latency
  (update + Q queries in ONE compiled step, ``SimRankSession.epoch``) and
  the rebuild + identical fused query dispatch.

Results land in ``benchmarks.common.RESULTS['dynamic']`` and are written to
``BENCH_dynamic.json`` by ``run.py`` (CI asserts freshness_speedup > 1).
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import RESULTS, emit, pick_query_nodes, timed
from repro.api import GraphHandle, SimRankSession
from repro.core import build_oneway_index, make_params, multi_source_topk
from repro.graph import erdos_renyi_graph, make_update_batch

C = 0.6
TOP_K = 50
B = 128  # ops per update batch
Q = 4  # queries per epoch


def _median(xs: list[float]) -> float:
    return float(np.median(np.array(xs)))


def run(quick: bool = True) -> None:
    n, m = (5_000, 50_000) if quick else (50_000, 500_000)
    rounds = 8 if quick else 32
    n_r = 512 if quick else 2048
    reps = 5 if quick else 10
    # Erdos-Renyi, not the hub-skewed power-law: this suite measures the
    # UPDATE machinery (buffer maintenance vs rebuild), and an unbounded hub
    # makes k_max ~ n, i.e. an O(n^2) ELL table whose copy cost swamps every
    # measurement on both paths.  Hub-skew probe behavior is bench_serve's
    # domain.
    src, dst, n = erdos_renyi_graph(n, m, seed=0)
    in_deg = np.bincount(dst, minlength=n)
    # headroom for every batch the suite streams: throughput rounds,
    # latency reps, and the epoch section's warmup + reps
    capacity = len(src) + B * (rounds + 2 * reps + 4)
    k_max = int(in_deg.max()) + 128
    handle = GraphHandle.from_edges(src, dst, n, capacity=capacity, k_max=k_max)
    rng = np.random.default_rng(1)

    def fresh_ops(r):
        return (rng.integers(0, n, B).astype(np.int32),
                rng.integers(0, n, B).astype(np.int32))

    # --- 1. sustained update throughput ------------------------------------
    batches = []
    for r in range(rounds):
        s, d = fresh_ops(r)
        batches.append(make_update_batch(s, d, True, batch_size=B, n=n))
    # compile once, then stream all rounds through the same step
    hw = handle.copy()
    hw.apply_batch(batches[0])
    jax.block_until_ready((hw.g.src, hw.eg.in_nbrs))
    hc = handle.copy()
    t0 = time.time()
    for b in batches:
        hc.apply_batch(b)
    jax.block_until_ready((hc.g.src, hc.eg.in_nbrs))
    t_inc = time.time() - t0
    inc_eps = B * rounds / t_inc
    emit("dynamic/incremental_update_eps", t_inc / rounds * 1e6,
         f"edges_per_sec={inc_eps:.0f}")

    hs, hd = src.copy(), dst.copy()
    t0 = time.time()
    for b in batches:
        bs = np.asarray(b.src)[np.asarray(b.src) < n]
        bd = np.asarray(b.dst)[np.asarray(b.dst) < n]
        hs = np.concatenate([hs, bs])
        hd = np.concatenate([hd, bd])
        h_rb = GraphHandle.from_edges(hs, hd, n, capacity=capacity, k_max=k_max)
        jax.block_until_ready((h_rb.g.src, h_rb.eg.in_nbrs))
    t_rb = time.time() - t0
    rb_eps = B * rounds / t_rb
    emit("dynamic/rebuild_update_eps", t_rb / rounds * 1e6,
         f"edges_per_sec={rb_eps:.0f}")

    # TSF's index maintenance cost after the same updates (the paper's §1
    # critique): one-way-graph rebuild, the cheapest index-based competitor
    _, t_tsf = timed(build_oneway_index, jax.random.key(0), hc.eg, r_g=50)
    emit("dynamic/tsf_index_rebuild_rg50", t_tsf * 1e6,
         f"vs_incremental_batch={t_tsf / max(t_inc / rounds, 1e-9):.0f}x")

    # --- 2. update->queryable latency --------------------------------------
    # incremental: the batch application IS the entire freshness gap — the
    # next fused dispatch reads the updated buffers directly
    inc_lat = []
    for r in range(reps):
        s, d = fresh_ops(rounds + r)
        batch = make_update_batch(s, d, True, batch_size=B, n=n)
        t0 = time.time()
        hc.apply_batch(batch)
        jax.block_until_ready((hc.g.src, hc.eg.in_nbrs))
        inc_lat.append(time.time() - t0)
        hs = np.concatenate([hs, s])
        hd = np.concatenate([hd, d])
    inc_queryable = _median(inc_lat)
    emit("dynamic/incremental_queryable_latency", inc_queryable * 1e6,
         f"batch={B}")

    # rebuild baseline: host rebuild of both mirrors from the updated edge
    # list, then device residency (what ANY rebuild-style competitor pays at
    # minimum before it can serve a fresh query)
    rb_lat = []
    for r in range(reps):
        t0 = time.time()
        h_rb = GraphHandle.from_edges(hs, hd, n, capacity=capacity, k_max=k_max)
        jax.block_until_ready((h_rb.g.src, h_rb.eg.in_nbrs))
        rb_lat.append(time.time() - t0)
    rb_queryable = _median(rb_lat)
    freshness_speedup = rb_queryable / inc_queryable
    emit("dynamic/rebuild_queryable_latency", rb_queryable * 1e6,
         f"speedup={freshness_speedup:.1f}x")

    # --- 3. end-to-end context: fused epoch vs rebuild + same query --------
    # both paths consume the IDENTICAL update stream from the identical
    # starting graph (the accumulated hs/hd edge list), so every rep
    # queries the same edge set: the session applies batch r to its owned
    # mirrors, the baseline rebuilds from the edge list as of batch r
    params = make_params(n, c=C, eps_a=0.1, delta=0.01)
    qnodes = [int(u) for u in pick_query_nodes(in_deg, Q, seed=2)]
    h3 = GraphHandle.from_edges(hs, hd, n, capacity=capacity, k_max=k_max)
    sess = SimRankSession(h3, c=C, eps_a=0.1, top_k=TOP_K,
                          batch_q=Q, update_batch=B, seed=0)
    # warm the compiled epoch step (its batch joins the shared stream)
    s, d = fresh_ops(99)
    sess.epoch(inserts=(s, d), queries=qnodes, budget_walks=n_r)
    hs = np.concatenate([hs, s])
    hd = np.concatenate([hd, d])
    epoch_lat = []
    snapshots = []
    for r in range(reps):
        s, d = fresh_ops(100 + r)
        ep = sess.epoch(inserts=(s, d), queries=qnodes, budget_walks=n_r)
        epoch_lat.append(ep.latency_s)
        hs = np.concatenate([hs, s])
        hd = np.concatenate([hd, d])
        snapshots.append((hs, hd))  # edge list as of this rep's batch
    epoch_s = _median(epoch_lat)
    emit("dynamic/epoch_update_plus_query", epoch_s * 1e6,
         f"B={B},Q={Q},n_r={n_r},version={sess.version}")

    keys = jax.random.split(jax.random.key(3), Q)
    us = jnp.asarray(qnodes, jnp.int32)
    h_rb = GraphHandle.from_edges(*snapshots[0], n, capacity=capacity,
                                  k_max=k_max)
    idx, vals = multi_source_topk(None, h_rb.g, h_rb.eg, us, TOP_K, params,
                                  lanes=256, n_r=n_r, keys=keys)
    jax.block_until_ready(idx)  # warm the query step
    rb_e2e = []
    for hs_r, hd_r in snapshots:
        t0 = time.time()
        h_rb = GraphHandle.from_edges(hs_r, hd_r, n, capacity=capacity,
                                      k_max=k_max)
        idx, vals = multi_source_topk(None, h_rb.g, h_rb.eg, us, TOP_K, params,
                                      lanes=256, n_r=n_r, keys=keys)
        jax.block_until_ready((idx, vals))
        rb_e2e.append(time.time() - t0)
    rb_e2e_s = _median(rb_e2e)
    emit("dynamic/rebuild_plus_query", rb_e2e_s * 1e6,
         f"vs_epoch={rb_e2e_s / epoch_s:.2f}x")

    RESULTS["dynamic"] = dict(
        n=n, m=int(m), update_batch=B, q=Q, n_r=n_r, rounds=rounds,
        incremental_update_eps=inc_eps,
        rebuild_update_eps=rb_eps,
        update_throughput_speedup=inc_eps / rb_eps,
        incremental_queryable_latency_s=inc_queryable,
        rebuild_queryable_latency_s=rb_queryable,
        freshness_speedup=freshness_speedup,
        epoch_update_plus_query_s=epoch_s,
        rebuild_plus_query_s=rb_e2e_s,
        tsf_index_rebuild_s=t_tsf,
        session_stats=sess.stats.as_dict(),
    )


if __name__ == "__main__":  # run as `python -m benchmarks.bench_dynamic`
    import sys

    from benchmarks.common import write_json

    run(quick="--full" not in sys.argv)
    write_json("BENCH_dynamic.json", quick="--full" not in sys.argv,
               suites=["dynamic"])
