"""Dynamic-graph serving: incremental maintenance vs rebuild-from-scratch.

The paper's §1 motivation made quantitative.  ProbeSim is index-free, so an
edge update is an O(1) buffer write into the capacity-padded COO/ELL
mirrors (owned by one ``GraphHandle``) and the next query is already exact
w.r.t. the new graph; index-based competitors must rebuild before the
first fresh query (TSF: the R_g one-way graphs; SLING: the whole index).
Two measurements against a rebuild-from-scratch baseline (rebuild the
handle from the updated host edge list — the cheapest possible "index",
i.e. a lower bound on any index-based competitor's maintenance cost):

* **sustained update throughput** (edges/sec): rounds of fixed-size update
  batches through the jitted coordinated apply (``GraphHandle.apply_batch``,
  both mirrors, on device) vs a host rebuild of the handle per batch;
* **update->queryable latency** (seconds): time from an update batch's
  arrival until the post-update graph state is resident and consistent on
  device, ready for the next fused query dispatch — the freshness gap a
  query observes.  For context we also report the fused epoch latency
  (update + Q queries in ONE compiled step, ``SimRankSession.epoch``) and
  the rebuild + identical fused query dispatch.

Results land in ``benchmarks.common.RESULTS['dynamic']`` and are written to
``BENCH_dynamic.json`` by ``run.py`` (CI asserts freshness_speedup > 1).
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import RESULTS, emit, pick_query_nodes, timed
from repro.api import GraphHandle, SimRankSession
from repro.core import build_oneway_index, make_params, multi_source_topk
from repro.graph import erdos_renyi_graph, make_update_batch

C = 0.6
TOP_K = 50
B = 128  # ops per update batch
Q = 4  # queries per epoch


def _median(xs: list[float]) -> float:
    return float(np.median(np.array(xs)))


def run(quick: bool = True, backend: str = "local") -> None:
    n, m = (5_000, 50_000) if quick else (50_000, 500_000)
    rounds = 8 if quick else 32
    n_r = 512 if quick else 2048
    reps = 5 if quick else 10
    # Erdos-Renyi, not the hub-skewed power-law: this suite measures the
    # UPDATE machinery (buffer maintenance vs rebuild), and an unbounded hub
    # makes k_max ~ n, i.e. an O(n^2) ELL table whose copy cost swamps every
    # measurement on both paths.  Hub-skew probe behavior is bench_serve's
    # domain.
    src, dst, n = erdos_renyi_graph(n, m, seed=0)
    in_deg = np.bincount(dst, minlength=n)
    # headroom for every batch the suite streams: throughput rounds,
    # latency reps, and the epoch section's warmup + reps
    capacity = len(src) + B * (rounds + 2 * reps + 4)
    k_max = int(in_deg.max()) + 128
    handle = GraphHandle.from_edges(src, dst, n, capacity=capacity, k_max=k_max)
    rng = np.random.default_rng(1)

    def fresh_ops(r):
        return (rng.integers(0, n, B).astype(np.int32),
                rng.integers(0, n, B).astype(np.int32))

    # --- 1. sustained update throughput ------------------------------------
    batches = []
    for r in range(rounds):
        s, d = fresh_ops(r)
        batches.append(make_update_batch(s, d, True, batch_size=B, n=n))
    # compile once, then stream all rounds through the same step
    hw = handle.copy()
    hw.apply_batch(batches[0])
    jax.block_until_ready((hw.g.src, hw.eg.in_nbrs))
    hc = handle.copy()
    t0 = time.time()
    for b in batches:
        hc.apply_batch(b)
    jax.block_until_ready((hc.g.src, hc.eg.in_nbrs))
    t_inc = time.time() - t0
    inc_eps = B * rounds / t_inc
    emit("dynamic/incremental_update_eps", t_inc / rounds * 1e6,
         f"edges_per_sec={inc_eps:.0f}")

    hs, hd = src.copy(), dst.copy()
    t0 = time.time()
    for b in batches:
        bs = np.asarray(b.src)[np.asarray(b.src) < n]
        bd = np.asarray(b.dst)[np.asarray(b.dst) < n]
        hs = np.concatenate([hs, bs])
        hd = np.concatenate([hd, bd])
        h_rb = GraphHandle.from_edges(hs, hd, n, capacity=capacity, k_max=k_max)
        jax.block_until_ready((h_rb.g.src, h_rb.eg.in_nbrs))
    t_rb = time.time() - t0
    rb_eps = B * rounds / t_rb
    emit("dynamic/rebuild_update_eps", t_rb / rounds * 1e6,
         f"edges_per_sec={rb_eps:.0f}")

    # TSF's index maintenance cost after the same updates (the paper's §1
    # critique): one-way-graph rebuild, the cheapest index-based competitor
    _, t_tsf = timed(build_oneway_index, jax.random.key(0), hc.eg, r_g=50)
    emit("dynamic/tsf_index_rebuild_rg50", t_tsf * 1e6,
         f"vs_incremental_batch={t_tsf / max(t_inc / rounds, 1e-9):.0f}x")

    # --- 2. update->queryable latency --------------------------------------
    # incremental: the batch application IS the entire freshness gap — the
    # next fused dispatch reads the updated buffers directly
    inc_lat = []
    for r in range(reps):
        s, d = fresh_ops(rounds + r)
        batch = make_update_batch(s, d, True, batch_size=B, n=n)
        t0 = time.time()
        hc.apply_batch(batch)
        jax.block_until_ready((hc.g.src, hc.eg.in_nbrs))
        inc_lat.append(time.time() - t0)
        hs = np.concatenate([hs, s])
        hd = np.concatenate([hd, d])
    inc_queryable = _median(inc_lat)
    emit("dynamic/incremental_queryable_latency", inc_queryable * 1e6,
         f"batch={B}")

    # rebuild baseline: host rebuild of both mirrors from the updated edge
    # list, then device residency (what ANY rebuild-style competitor pays at
    # minimum before it can serve a fresh query)
    rb_lat = []
    for r in range(reps):
        t0 = time.time()
        h_rb = GraphHandle.from_edges(hs, hd, n, capacity=capacity, k_max=k_max)
        jax.block_until_ready((h_rb.g.src, h_rb.eg.in_nbrs))
        rb_lat.append(time.time() - t0)
    rb_queryable = _median(rb_lat)
    freshness_speedup = rb_queryable / inc_queryable
    emit("dynamic/rebuild_queryable_latency", rb_queryable * 1e6,
         f"speedup={freshness_speedup:.1f}x")

    # --- 3. end-to-end context: fused epoch vs rebuild + same query --------
    # both paths consume the IDENTICAL update stream from the identical
    # starting graph (the accumulated hs/hd edge list), so every rep
    # queries the same edge set: the session applies batch r to its owned
    # mirrors, the baseline rebuilds from the edge list as of batch r.
    # The two legs are INTERLEAVED rep-by-rep (epoch r, then rebuild r)
    # and the headline speedup is the median of the per-rep PAIRED ratios:
    # sequential whole-leg timing is sensitive to which leg catches a
    # CPU-frequency / allocator noise burst (30%+ across-run swings on this
    # suite's sub-second legs flipped the ratio run to run); pairing
    # cancels the common-mode noise because adjacent reps share it.
    params = make_params(n, c=C, eps_a=0.1, delta=0.01)
    qnodes = [int(u) for u in pick_query_nodes(in_deg, Q, seed=2)]
    h3 = GraphHandle.from_edges(hs, hd, n, capacity=capacity, k_max=k_max)
    sess = SimRankSession(h3, c=C, eps_a=0.1, top_k=TOP_K,
                          batch_q=Q, update_batch=B, seed=0)
    keys = jax.random.split(jax.random.key(3), Q)
    us = jnp.asarray(qnodes, jnp.int32)
    # warm BOTH compiled steps (the warmup batch joins the shared stream)
    s, d = fresh_ops(99)
    sess.epoch(inserts=(s, d), queries=qnodes, budget_walks=n_r)
    hs = np.concatenate([hs, s])
    hd = np.concatenate([hd, d])
    h_rb = GraphHandle.from_edges(hs, hd, n, capacity=capacity, k_max=k_max)
    idx, vals = multi_source_topk(None, h_rb.g, h_rb.eg, us, TOP_K, params,
                                  lanes=256, n_r=n_r, keys=keys)
    jax.block_until_ready(idx)
    epoch_lat, rb_e2e, paired = [], [], []
    reps3 = max(reps, 8)  # the paired ratio wants more draws than the legs
    for r in range(reps3):
        s, d = fresh_ops(100 + r)
        hs = np.concatenate([hs, s])
        hd = np.concatenate([hd, d])

        # rebuild leg against the edge list as of THIS batch, timed
        # back-to-back with the epoch it pairs against
        def rebuild_leg():
            t0 = time.time()
            h_rb = GraphHandle.from_edges(hs, hd, n, capacity=capacity,
                                          k_max=k_max)
            idx, vals = multi_source_topk(None, h_rb.g, h_rb.eg, us, TOP_K,
                                          params, lanes=256, n_r=n_r,
                                          keys=keys)
            jax.block_until_ready((idx, vals))
            return time.time() - t0

        # alternate the leg order per rep: adjacent legs share any
        # common-mode noise burst either way, and alternation cancels the
        # residual ordering bias (allocator/cache state one leg leaves
        # for the other) that a fixed epoch-first order bakes in
        if r % 2:
            rb = rebuild_leg()
            ep = sess.epoch(inserts=(s, d), queries=qnodes,
                            budget_walks=n_r)
        else:
            ep = sess.epoch(inserts=(s, d), queries=qnodes,
                            budget_walks=n_r)
            rb = rebuild_leg()
        epoch_lat.append(ep.latency_s)
        rb_e2e.append(rb)
        paired.append(rb / ep.latency_s)
    epoch_s = _median(epoch_lat)
    emit("dynamic/epoch_update_plus_query", epoch_s * 1e6,
         f"B={B},Q={Q},n_r={n_r},version={sess.version}")
    rb_e2e_s = _median(rb_e2e)
    epoch_speedup = _median(paired)
    emit("dynamic/rebuild_plus_query", rb_e2e_s * 1e6,
         f"paired_speedup={epoch_speedup:.2f}x")

    RESULTS["dynamic"] = dict(
        n=n, m=int(m), update_batch=B, q=Q, n_r=n_r, rounds=rounds,
        incremental_update_eps=inc_eps,
        rebuild_update_eps=rb_eps,
        update_throughput_speedup=inc_eps / rb_eps,
        incremental_queryable_latency_s=inc_queryable,
        rebuild_queryable_latency_s=rb_queryable,
        freshness_speedup=freshness_speedup,
        epoch_update_plus_query_s=epoch_s,
        rebuild_plus_query_s=rb_e2e_s,
        # median of per-rep (rebuild+query)/(epoch) ratios — the paired,
        # order-alternated estimator the interleaved section 3 exists
        # for.  On the quick config the rebuild cost is <1% of the
        # query-dominated leg, so parity (~1.0) is the expected value;
        # the isolated update->queryable advantage is freshness_speedup
        epoch_vs_rebuild_speedup=epoch_speedup,
        tsf_index_rebuild_s=t_tsf,
        session_stats=sess.stats.as_dict(),
    )
    if backend == "sharded":
        RESULTS["dynamic"]["sharded"] = _run_sharded_leg(quick)


def _run_sharded_leg(quick: bool) -> dict:
    """Incremental-vs-rebuild freshness on the MESH epoch path.

    The sharded analogue of section 2: an update batch arrives, how long
    until the post-update graph is device-resident and queryable?
    *Incremental* is the fused mesh epoch against the CARRIED device
    shard buffers (``core.epoch``: shard_map apply, donation per shard);
    *rebuild* forces the device mirror to be rebuilt from the host edge
    list before the same compiled epoch step (what any rebuild-style
    maintenance pays at minimum).  Sized for the CPU smoke mesh — an
    integration datapoint (8 fake host devices share one CPU), the ratio
    not the absolute numbers is the claim (CI gates > 1).
    """
    shards = len(jax.devices())
    n_s, m_s = (2_000, 20_000) if quick else (10_000, 100_000)
    B_s, Q_s, n_r_s, reps = 64, 2, 128, (5 if quick else 10)
    src, dst, n_s = erdos_renyi_graph(n_s, m_s, seed=0)
    in_deg = np.bincount(dst, minlength=n_s)
    handle = GraphHandle.from_edges(
        src, dst, n_s,
        capacity=len(src) + B_s * (4 * reps + 8),
        k_max=int(in_deg.max()) + 64,
    )
    sess = SimRankSession(
        handle, c=C, eps_a=0.1, top_k=TOP_K, batch_q=Q_s, update_batch=B_s,
        walk_chunk=64, seed=0, backend="sharded", shards=shards,
    )
    rng = np.random.default_rng(7)

    def burst():
        return (rng.integers(0, n_s, B_s).astype(np.int32),
                rng.integers(0, n_s, B_s).astype(np.int32))

    qnodes = [int(u) for u in pick_query_nodes(in_deg, Q_s, seed=3)]
    # warm both compiled epoch variants (update-only + update->query)
    sess.epoch(inserts=burst(), queries=qnodes, budget_walks=n_r_s)
    sess.epoch(inserts=burst())

    # incremental: the carried device mirror absorbs the batch in the
    # compiled shard_map step — this IS the freshness gap
    inc = []
    for _ in range(reps):
        ep = sess.epoch(inserts=burst())
        inc.append(ep.latency_s)
    inc_s = _median(inc)
    emit("dynamic/sharded_incremental_epoch_apply", inc_s * 1e6,
         f"B={B_s},shards={shards}")

    # rebuild baseline: drop the carried mirror before each batch, so the
    # epoch pays the host-side re-partition + ELL fill + device upload
    # before the SAME compiled apply step
    rb = []
    for _ in range(reps):
        sess.backend._epoch_graph = None  # force mirror rebuild from host
        ep = sess.epoch(inserts=burst())
        rb.append(ep.latency_s)
    rb_s = _median(rb)
    speedup = rb_s / inc_s
    emit("dynamic/sharded_rebuild_epoch_apply", rb_s * 1e6,
         f"speedup={speedup:.1f}x")

    # context: one fused update->query epoch on the carried mirror
    eq = []
    for _ in range(reps):
        ep = sess.epoch(inserts=burst(), queries=qnodes,
                        budget_walks=n_r_s)
        eq.append(ep.latency_s)
    eq_s = _median(eq)
    emit("dynamic/sharded_epoch_update_plus_query", eq_s * 1e6,
         f"B={B_s},Q={Q_s},n_r={n_r_s},version={sess.version}")

    return dict(
        backend="sharded",
        shards=int(shards),
        n=int(n_s), m=int(m_s), update_batch=B_s, q=Q_s, n_r=n_r_s,
        reps=reps,
        incremental_epoch_apply_s=inc_s,
        rebuild_epoch_apply_s=rb_s,
        freshness_speedup=speedup,
        epoch_update_plus_query_s=eq_s,
        session_stats=sess.stats.as_dict(),
    )


if __name__ == "__main__":  # run as `python -m benchmarks.bench_dynamic`
    import argparse

    from benchmarks.common import write_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=("local", "sharded"),
                    default="local")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(quick=not args.full, backend=args.backend)
    write_json("BENCH_dynamic.json", quick=not args.full,
               suites=["dynamic"])
