"""Paper §1 motivation table: dynamic-update cost.

ProbeSim (index-free): an edge update is an O(1) buffer write and the next
query is already exact w.r.t. the new graph.  TSF: the one-way-graph index
must be rebuilt (the paper's SLING/TSF critique).  We measure both."""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import emit, timed
from repro.core import build_oneway_index, make_params, single_source
from repro.graph import ell_from_edges, graph_from_edges, powerlaw_graph
from repro.graph.dynamic import insert_edges, insert_edges_ell


def run(quick: bool = True) -> None:
    n, m = (5_000, 50_000) if quick else (50_000, 500_000)
    src, dst, n = powerlaw_graph(n, m, seed=0)
    g = graph_from_edges(src, dst, n, capacity=len(src) + 4096)
    in_deg = np.bincount(dst, minlength=n)
    eg = ell_from_edges(src, dst, n, k_max=int(in_deg.max()) + 64)
    rng = np.random.default_rng(1)

    batch = 128
    new_src = jax.numpy.asarray(rng.integers(0, n, batch).astype(np.int32))
    new_dst = jax.numpy.asarray(rng.integers(0, n, batch).astype(np.int32))

    _, t_ins = timed(insert_edges, g, new_src, new_dst, reps=5)
    _, t_ins_ell = timed(insert_edges_ell, eg, new_src, new_dst, reps=5)
    emit("dynamic/insert_coo_128", t_ins * 1e6, "index_free=true")
    emit("dynamic/insert_ell_128", t_ins_ell * 1e6, "index_free=true")

    # TSF index rebuild cost after the same update
    _, t_rebuild = timed(build_oneway_index, jax.random.key(0), eg, r_g=50)
    emit("dynamic/tsf_index_rebuild_rg50", t_rebuild * 1e6,
         f"vs_insert={t_rebuild / max(t_ins, 1e-9):.0f}x")

    # end-to-end: update then query (freshness costs nothing extra)
    params = make_params(n, c=0.6, eps_a=0.1, delta=0.01,
                         n_r_override=512 if quick else None)
    g2 = insert_edges(g, new_src, new_dst)
    eg2 = insert_edges_ell(eg, new_src, new_dst)
    u = int(np.argmax(in_deg))
    _, t_q = timed(
        single_source, jax.random.key(0), g2, eg2, u, params, variant="telescoped"
    )
    emit("dynamic/query_after_update", t_q * 1e6, f"n_r={params.n_r}")


if __name__ == "__main__":
    run(quick=False)
