"""Benchmark harness entry: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
``--full`` runs paper-scale sweeps; default is the CPU-quick profile.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: abserror,topk,large,dynamic,kernels")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (
        bench_abserror,
        bench_dynamic,
        bench_kernels,
        bench_large,
        bench_topk,
    )

    suites = dict(
        abserror=bench_abserror.run,
        topk=bench_topk.run,
        large=bench_large.run,
        dynamic=bench_dynamic.run,
        kernels=bench_kernels.run,
    )
    chosen = args.only.split(",") if args.only else list(suites)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in chosen:
        print(f"# suite: {name}", file=sys.stderr)
        suites[name](quick=quick)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
