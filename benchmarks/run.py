"""Benchmark harness entry: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit) and
writes machine-readable artifacts: ``BENCH_serve.json`` (serving queries/sec
for the serial vs fused-batched drain) when the serve suite runs and
``BENCH_dynamic.json`` (incremental vs rebuild update throughput and
update->queryable latency) when the dynamic suite runs, each also carrying
every emitted row.  ``--full`` runs paper-scale sweeps; default (``--quick``)
is the CPU-quick profile.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# allow both `python -m benchmarks.run` and `python benchmarks/run.py`
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="CPU-quick profile (the default; negates --full)")
    ap.add_argument("--only", default=None,
                    help="comma list: serve,abserror,topk,large,dynamic,kernels")
    ap.add_argument("--json", default=None,
                    help="machine-readable output path; by default "
                         "BENCH_serve.json is written iff the serve suite ran "
                         "(so other suites never clobber the serve artifact)")
    args = ap.parse_args()
    quick = not args.full or args.quick

    from benchmarks import (
        bench_abserror,
        bench_dynamic,
        bench_kernels,
        bench_large,
        bench_serve,
        bench_topk,
    )
    from benchmarks.common import write_json

    suites = dict(
        serve=bench_serve.run,
        abserror=bench_abserror.run,
        topk=bench_topk.run,
        large=bench_large.run,
        dynamic=bench_dynamic.run,
        kernels=bench_kernels.run,
    )
    chosen = args.only.split(",") if args.only else list(suites)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in chosen:
        print(f"# suite: {name}", file=sys.stderr)
        suites[name](quick=quick)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
    if args.json:
        write_json(args.json, quick=quick, suites=chosen)
    else:
        # one artifact per acceptance consumer, written iff its suite ran
        # (so other suites never clobber an existing artifact)
        if "serve" in chosen:
            write_json("BENCH_serve.json", quick=quick, suites=chosen)
        if "dynamic" in chosen:
            write_json("BENCH_dynamic.json", quick=quick, suites=chosen)


if __name__ == "__main__":
    main()
