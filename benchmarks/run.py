"""Benchmark harness entry: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit) and
writes machine-readable artifacts: ``BENCH_serve.json`` (serving queries/sec
for the serial vs fused-batched drain) when the serve suite runs,
``BENCH_dynamic.json`` (incremental vs rebuild update throughput and
update->queryable latency) when the dynamic suite runs, and
``BENCH_abserror.json`` (the adaptive-controller epsilon sweep: walks used,
oracle max-abs-error vs certified bound, precision@10, walks saved vs the
flat budget) when the abserror suite runs, and ``BENCH_kernels.json`` (the
fused lane-probe kernel vs the XLA lane-level oracle with roofline records)
when the kernels suite runs — each also carrying every emitted row.  ``--full`` runs paper-scale sweeps; default (``--quick``) is
the CPU-quick profile.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# allow both `python -m benchmarks.run` and `python benchmarks/run.py`
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="CPU-quick profile (the default; negates --full)")
    ap.add_argument("--only", default=None,
                    help="comma list: serve,service,abserror,topk,large,"
                         "dynamic,kernels,stream")
    ap.add_argument("--backend", choices=("local", "sharded"), default="local",
                    help="forwarded to suites that take it (serve, dynamic, "
                         "service, stream): 'sharded' adds the mesh-backend "
                         "comparison rows")
    ap.add_argument("--json", default=None,
                    help="machine-readable output path; by default "
                         "BENCH_serve.json is written iff the serve suite ran "
                         "(so other suites never clobber the serve artifact)")
    args = ap.parse_args()
    quick = not args.full or args.quick

    from benchmarks import (
        bench_abserror,
        bench_dynamic,
        bench_kernels,
        bench_large,
        bench_serve,
        bench_service,
        bench_stream,
        bench_topk,
    )
    from benchmarks.common import RESULTS, ROWS, write_json

    suites = dict(
        serve=bench_serve.run,
        service=bench_service.run,
        abserror=bench_abserror.run,
        topk=bench_topk.run,
        large=bench_large.run,
        dynamic=bench_dynamic.run,
        kernels=bench_kernels.run,
        stream=bench_stream.run,
    )
    takes_backend = {"serve", "dynamic", "service", "stream"}  # mesh legs
    # suites that must fill RESULTS[name]; abserror is structured too — it
    # used to print CSV rows and silently drop its metrics, so the
    # accuracy-gate job had nothing machine-readable to enforce
    structured = {"serve", "dynamic", "abserror", "service", "stream",
                  "kernels"}
    chosen = args.only.split(",") if args.only else list(suites)
    unknown = [name for name in chosen if name not in suites]
    if unknown:
        ap.error(f"unknown suite(s): {', '.join(unknown)} "
                 f"(have: {', '.join(suites)})")
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in chosen:
        print(f"# suite: {name}", file=sys.stderr)
        rows_before = len(ROWS)
        if name in takes_backend:
            suites[name](quick=quick, backend=args.backend)
        else:
            suites[name](quick=quick)
        # fail LOUDLY when a requested suite produced nothing: a silently
        # empty artifact reads as "benchmark ran" to every downstream
        # consumer (CI gates, acceptance checks) when it did not
        if len(ROWS) == rows_before:
            sys.exit(f"suite '{name}' was requested but emitted no rows")
        if name in structured and name not in RESULTS:
            sys.exit(f"suite '{name}' was requested but exported no "
                     f"RESULTS['{name}'] row for its JSON artifact")
        if (name in takes_backend and args.backend == "sharded"
                and "backend" not in RESULTS[name]
                and "sharded" not in RESULTS[name]):
            sys.exit(f"suite '{name}' ran with --backend sharded but "
                     "exported no sharded comparison row")
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
    if args.json:
        write_json(args.json, quick=quick, suites=chosen)
    else:
        # one artifact per acceptance consumer, written iff its suite ran
        # (so other suites never clobber an existing artifact)
        if "serve" in chosen or "service" in chosen:
            write_json("BENCH_serve.json", quick=quick, suites=chosen)
        if "dynamic" in chosen:
            write_json("BENCH_dynamic.json", quick=quick, suites=chosen)
        if "abserror" in chosen:
            write_json("BENCH_abserror.json", quick=quick, suites=chosen)
        if "stream" in chosen:
            write_json("BENCH_stream.json", quick=quick, suites=chosen)
        if "kernels" in chosen:
            write_json("BENCH_kernels.json", quick=quick, suites=chosen)


if __name__ == "__main__":
    main()
