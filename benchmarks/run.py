"""Benchmark harness entry: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit) and
writes a machine-readable ``BENCH_serve.json`` (serving queries/sec for the
serial vs fused-batched drain, plus every emitted row — e.g. the kernel
timings).  ``--full`` runs paper-scale sweeps; default (``--quick``) is the
CPU-quick profile.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# allow both `python -m benchmarks.run` and `python benchmarks/run.py`
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="CPU-quick profile (the default; negates --full)")
    ap.add_argument("--only", default=None,
                    help="comma list: serve,abserror,topk,large,dynamic,kernels")
    ap.add_argument("--json", default=None,
                    help="machine-readable output path; by default "
                         "BENCH_serve.json is written iff the serve suite ran "
                         "(so other suites never clobber the serve artifact)")
    args = ap.parse_args()
    quick = not args.full or args.quick

    from benchmarks import (
        bench_abserror,
        bench_dynamic,
        bench_kernels,
        bench_large,
        bench_serve,
        bench_topk,
    )
    from benchmarks.common import write_json

    suites = dict(
        serve=bench_serve.run,
        abserror=bench_abserror.run,
        topk=bench_topk.run,
        large=bench_large.run,
        dynamic=bench_dynamic.run,
        kernels=bench_kernels.run,
    )
    chosen = args.only.split(",") if args.only else list(suites)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in chosen:
        print(f"# suite: {name}", file=sys.stderr)
        suites[name](quick=quick)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
    json_path = args.json
    if json_path is None and "serve" in chosen:
        json_path = "BENCH_serve.json"
    if json_path:
        write_json(json_path, quick=quick, suites=chosen)


if __name__ == "__main__":
    main()
