"""Shared benchmark utilities: timed runs + CSV emission + JSON export."""
from __future__ import annotations

import json
import time

import numpy as np

import jax

ROWS: list[str] = []
RESULTS: dict = {}  # structured results (e.g. the serve suite's qps numbers)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


PROMOTED = ("serve", "dynamic", "abserror", "service", "stream", "kernels")


def write_json(path: str, *, quick: bool, suites: list[str]) -> None:
    """Machine-readable dump: structured RESULTS + every emitted CSV row.

    Promoted suite blocks (the top-level keys CI acceptance gates read)
    from an EXISTING artifact at ``path`` are carried forward when the
    current run didn't produce them — so ``bench_serve --backend sharded``
    followed by ``bench_service`` compose one artifact instead of each
    leg nulling out the others' rows.
    """
    rows = []
    for row in ROWS:
        name, us, derived = row.split(",", 2)
        rows.append({"name": name, "us_per_call": float(us),
                     "derived": derived})
    payload = dict(
        quick=quick,
        suites=suites,
        backend=jax.default_backend(),
        results=dict(RESULTS),
        rows=rows,
    )
    prior = read_prior_json(path)
    for key in PROMOTED:  # artifacts CI gates read at the top level
        if key in RESULTS:
            payload[key] = RESULTS[key]
        elif key in prior:  # preserved from the last run that had it
            payload[key] = prior[key]
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {path}", flush=True)


def read_prior_json(path: str) -> dict:
    """The existing artifact at ``path``, or {} (missing/corrupt)."""
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}


def timed(fn, *args, reps: int = 1, **kwargs):
    """(result, seconds/rep) with block_until_ready on jax outputs."""
    out = fn(*args, **kwargs)  # warmup/compile
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    return out, (time.time() - t0) / reps


def pick_query_nodes(in_deg: np.ndarray, k: int, seed: int = 0) -> np.ndarray:
    """Paper protocol: uniform over nodes with nonzero in-degree."""
    rng = np.random.default_rng(seed)
    cand = np.where(in_deg > 0)[0]
    return rng.choice(cand, size=min(k, len(cand)), replace=False)
