"""Service load bench: closed-loop HTTP clients against the live server.

Protocol (acceptance: >= 256 concurrent in-flight queries complete with 0
unhandled errors; ``BENCH_serve.json["service"]`` records p50/p99 latency,
qps, the batch-size histogram, and shed/429 counts):

* graph: the ``bench_large.py`` quick config (livejournal stand-in, same
  as ``bench_serve.py``), one :class:`SimRankService` over it behind the
  threaded HTTP server (``serving/server.py``) on loopback;
* ``CLIENTS`` closed-loop client threads, each holding ONE keep-alive
  connection and issuing ``per_client`` sequential queries (so the
  in-flight population is the full client herd minus whoever is between
  requests) against a ``max_inflight`` bound BELOW the herd size — the
  429 + ``Retry-After`` path is part of the measured protocol, not an
  error;
* per-request wall latency (enqueue-to-response, including 429 backoff)
  feeds the p50/p99 figures; the service's own counters supply the
  batch-size histogram and the shed/429/5xx tallies;
* the gate is honest end-to-end: any client-side exception or 5xx is an
  unhandled error and fails the bench.

Results land in ``RESULTS['service']`` (promoted to the top level of
``BENCH_serve.json`` next to the ``serve`` rows — ``write_json`` carries
the other suite's rows forward).
"""
from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import RESULTS, emit, pick_query_nodes
from repro.api import GraphHandle
from repro.graph import paper_dataset
from repro.serving import ServiceClient, ServiceConfig, SimRankService
from repro.serving import start_server, stop_server

C = 0.6
CLIENTS = 256  # concurrent in-flight herd (acceptance floor)
TOP_K = 50
WALK_CHUNK = 256


def run(
    quick: bool = True,
    backend: str = "local",
    clients: int = CLIENTS,
) -> dict:
    name, scale = ("livejournal", 0.004)  # bench_large quick config
    budget = 64 if quick else 512
    per_client = 2 if quick else 8
    src, dst, n = paper_dataset(name, scale=scale)
    in_deg = np.bincount(dst, minlength=n)
    handle = GraphHandle.from_edges(src, dst, n, k_max=int(in_deg.max()) + 1)
    queries = pick_query_nodes(in_deg, 64)

    cfg = ServiceConfig(
        batch_window_ms=20.0,
        max_batch_q=16,
        # bound BELOW the herd so backpressure is exercised, not just
        # configured: ~1/3 of the herd queues, the rest sees 429 + retry
        max_inflight=max(2, int(clients * 0.75)),
        default_budget_walks=budget,
    )
    svc = SimRankService(
        handle, backend=backend, config=cfg,
        session_kwargs=dict(c=C, eps_a=0.1, walk_chunk=WALK_CHUNK,
                            top_k=TOP_K),
    )
    server, thread = start_server(svc)
    host, port = server.server_address

    # warm the fused-step compile cache before opening the floodgates so
    # the timed window measures serving, not one giant first-batch trace
    with ServiceClient(host, port) as cl:
        cl.query(node=int(queries[0]), k=10)

    latencies: list[list[float]] = [[] for _ in range(clients)]
    failures: list[str] = []
    barrier = threading.Barrier(clients + 1)

    def client_loop(ci: int) -> None:
        try:
            with ServiceClient(host, port) as cl:
                barrier.wait()
                for j in range(per_client):
                    u = int(queries[(ci * per_client + j) % len(queries)])
                    t0 = time.monotonic()
                    r = cl.query(node=u, k=10, seed=ci * 10_000 + j)
                    latencies[ci].append(time.monotonic() - t0)
                    if len(r["topk_nodes"]) != 10:
                        raise RuntimeError(f"short topk: {r['topk_nodes']}")
        except Exception as e:  # noqa: BLE001 — every failure is a gate
            failures.append(f"client {ci}: {type(e).__name__}: {e}")

    threads = [
        threading.Thread(target=client_loop, args=(i,), daemon=True)
        for i in range(clients)
    ]
    for t in threads:
        t.start()
    barrier.wait()  # all clients connected: the herd fires together
    t_load0 = time.monotonic()
    for t in threads:
        t.join(timeout=600)
    t_load = time.monotonic() - t_load0
    alive = sum(t.is_alive() for t in threads)

    stats = svc.stats_snapshot()["service"]
    stop_server(server, thread)

    lat = np.array([x for per in latencies for x in per])
    total = clients * per_client
    unhandled = len(failures) + alive + stats["errors_5xx"]
    if failures:
        for f in failures[:10]:
            print(f"# FAIL {f}", flush=True)
    p50 = float(np.percentile(lat, 50)) if lat.size else None
    p99 = float(np.percentile(lat, 99)) if lat.size else None
    qps = lat.size / t_load if t_load > 0 else 0.0
    emit(
        f"service/{name}/load_c{clients}",
        (t_load / max(lat.size, 1)) * 1e6,
        f"qps={qps:.2f};p50_s={p50:.4f};p99_s={p99:.4f};"
        f"served={stats['served']};rejected_429={stats['rejected_429']};"
        f"shed_504={stats['shed_504']};errors_5xx={stats['errors_5xx']};"
        f"batches={stats['batches']};unhandled={unhandled}",
    )
    RESULTS["service"] = dict(
        dataset=name,
        scale=scale,
        n=int(n),
        m=int(len(src)),
        backend=backend,
        clients=clients,
        per_client=per_client,
        total_queries=total,
        completed=int(lat.size),
        budget_walks=budget,
        batch_window_ms=cfg.batch_window_ms,
        max_batch_q=cfg.max_batch_q,
        max_inflight=cfg.max_inflight,
        qps=float(qps),
        p50_s=p50,
        p99_s=p99,
        elapsed_s=float(t_load),
        batch_hist=stats["batch_hist"],
        accepted=stats["accepted"],
        served=stats["served"],
        rejected_429=stats["rejected_429"],
        shed_504=stats["shed_504"],
        errors_5xx=stats["errors_5xx"],
        batches=stats["batches"],
        unhandled_errors=unhandled,
        failures=failures[:10],
    )
    return RESULTS["service"]


if __name__ == "__main__":
    import argparse

    from benchmarks.common import write_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=("local", "sharded"),
                    default="local")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--clients", type=int, default=CLIENTS)
    args = ap.parse_args()
    run(quick=not args.full, backend=args.backend, clients=args.clients)
    write_json("BENCH_serve.json", quick=not args.full,
               suites=["service"])
