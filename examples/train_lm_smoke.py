"""Train a small LM (llama3.2-1b reduced config) with the full substrate:
prefetching pipeline, AdamW, async checkpointing, and a simulated node
failure + restart (fault-tolerance demo).

Run:  PYTHONPATH=src python examples/train_lm_smoke.py
"""
import shutil
import tempfile

from repro.launch.train import train


def main():
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        print("=== phase 1: train with a failure injected at step 30 ===")
        try:
            train("llama3.2-1b", "train_4k", smoke=True, steps=60,
                  ckpt_dir=ckpt_dir, ckpt_every=10, fail_at=30)
        except RuntimeError as e:
            print(f"!! {e} — restarting from the latest checkpoint")

        print("=== phase 2: restart resumes from the checkpoint ===")
        out = train("llama3.2-1b", "train_4k", smoke=True, steps=60,
                    ckpt_dir=ckpt_dir, ckpt_every=10)
        print(f"resumed and finished: loss {out['first_loss']:.3f} -> "
              f"{out['last_loss']:.3f} in {out['seconds']:.1f}s")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
