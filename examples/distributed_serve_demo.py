"""Distributed ProbeSim serving demo on a local 8-device mesh.

Runs the SAME serve step that the 512-chip dry-run compiles — auto-partitioned
baseline and the ring/bf16 §Perf variant — on 8 fake CPU devices, verifying
they return identical top-k and timing both.

Run:  PYTHONPATH=src python examples/distributed_serve_demo.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ProbeSimConfig
from repro.core.distributed import build_sharded_graph, graph_specs, make_serve_step
from repro.core.ring import build_ring_graph, make_ring_serve_step, ring_graph_specs
from repro.graph import powerlaw_graph


def main():
    from repro.utils.jaxcompat import make_mesh, set_mesh, specs_to_shardings

    mesh = make_mesh((2, 4), ("data", "model"))
    src, dst, n = powerlaw_graph(20_000, 200_000, seed=0)
    cfg = ProbeSimConfig(name="demo", n=n, m=len(src), c=0.6)
    Q, B, L, K = 4, 64, 8, 10
    queries = jnp.asarray(np.unique(dst)[:Q].astype(np.int32))
    key = jax.random.key(0)

    sg = build_sharded_graph(src, dst, n, pad_nodes=32, pad_edges=256)
    rg = build_ring_graph(src, dst, n, shards=4)

    with set_mesh(mesh):
        auto = jax.jit(
            make_serve_step(cfg, queries=Q, walk_chunk=B, max_len=L, top_k=K,
                            edge_chunks=4),
            in_shardings=specs_to_shardings(
                (graph_specs(sg), P(), P()), mesh=mesh),
        )
        ring = jax.jit(
            make_ring_serve_step(cfg, queries=Q, walk_chunk=B, max_len=L,
                                 top_k=K, frontier_dtype=jnp.bfloat16),
            in_shardings=specs_to_shardings(
                (ring_graph_specs(rg), P(), P()), mesh=mesh),
        )

        for name, fn, g in [("auto-partitioned", auto, sg),
                            ("ring+bf16      ", ring, rg)]:
            idx, vals = jax.block_until_ready(fn(g, queries, key))  # compile
            t0 = time.time()
            for _ in range(3):
                idx, vals = jax.block_until_ready(fn(g, queries, key))
            dt = (time.time() - t0) / 3
            print(f"{name}: {dt*1e3:7.1f} ms/step  "
                  f"q0 top3={np.asarray(idx[0][:3]).tolist()} "
                  f"scores={np.round(np.asarray(vals[0][:3], np.float32), 4).tolist()}")

        a_idx, _ = auto(sg, queries, key)
        r_idx, _ = ring(rg, queries, key)
        same = all(
            set(np.asarray(a_idx[q]).tolist()) == set(np.asarray(r_idx[q]).tolist())
            for q in range(Q)
        )
        print(f"top-{K} sets identical across implementations: {same}")


if __name__ == "__main__":
    main()
