"""End-to-end driver (the paper's deployment story): serve batched top-k
SimRank queries on a DYNAMIC graph with the fused update->query epoch engine.

Each ``DynamicEngine.step()`` is ONE compiled dispatch that applies a padded
batch of edge insertions/deletions to both device mirrors and serves a batch
of queries on the just-updated graph — zero host transfers between update
and query, zero index rebuilds (contrast TSF/SLING).  Every result is
stamped with the graph ``version`` it was computed against, and capacity
overflow auto-regrows the buffers without losing updates.

Run:  PYTHONPATH=src python examples/dynamic_graph_serving.py
"""
import numpy as np

from repro.graph import ell_from_edges, graph_from_edges, powerlaw_graph
from repro.serving.dynamic_engine import DynamicEngine


def main():
    rng = np.random.default_rng(0)
    src, dst, n = powerlaw_graph(5_000, 60_000, seed=0, max_deg=512)
    in_deg = np.bincount(dst, minlength=n)
    g = graph_from_edges(src, dst, n, capacity=len(src) + 10_000)
    eg = ell_from_edges(src, dst, n, k_max=int(in_deg.max()) + 64)
    engine = DynamicEngine(
        g, eg, c=0.6, eps_a=0.1, top_k=10,
        batch_q=4, update_batch=64, walk_chunk=256, seed=0,
    )
    print(f"graph n={n} m={len(src)}; n_r={engine.params.n_r} walks/query; "
          f"epoch = {engine.update_batch} update ops + "
          f"{engine.batch_q} queries, one compiled dispatch")

    queries = rng.choice(np.where(in_deg > 0)[0], 12)
    for i in range(3):
        # enqueue an update burst: 60 inserts + a few deletions of originals
        engine.insert(rng.integers(0, n, 60).astype(np.int32),
                      rng.integers(0, n, 60).astype(np.int32))
        engine.delete(src[i * 4:i * 4 + 4], dst[i * 4:i * 4 + 4])
        for u in queries[i * 4:(i + 1) * 4]:
            engine.submit(int(u))
        ep = engine.step(budget_walks=512)
        print(f"epoch {i}: v{ep.version} "
              f"updates {ep.updates_applied}/{ep.updates_submitted} applied"
              f"{' (overflow->regrown)' if ep.regrown else ''}, "
              f"{len(ep.results)} queries in {ep.latency_s:.2f}s")
        for res in ep.results[:2]:
            print(f"  u={res.node} @v{res.version} "
                  f"top3={list(res.topk_nodes[:3])} "
                  f"scores={[round(float(s), 4) for s in res.topk_scores[:3]]}")
    s = engine.stats
    print(f"served {s.queries} queries across {s.epochs} epochs, "
          f"{s.updates_applied} edge updates applied, {s.regrows} regrows — "
          f"zero index rebuilds (index-free)")


if __name__ == "__main__":
    main()
