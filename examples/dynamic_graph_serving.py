"""End-to-end driver (the paper's deployment story): serve batched top-k
SimRank queries on a DYNAMIC graph with fused update->query session epochs.

Each ``SimRankSession.epoch()`` is ONE compiled dispatch that applies a
padded batch of edge insertions/deletions to both device mirrors (owned by
the session's ``GraphHandle``) and serves a batch of queries on the
just-updated graph — zero host transfers between update and query, zero
index rebuilds (contrast TSF/SLING).  Every result is stamped with the
graph ``version`` it was computed against plus the Thm-1 error bound at
the walk budget actually spent, and capacity overflow auto-regrows the
buffers without losing updates.

The epoch is a Backend stage (core/epoch.py): ``--backend sharded``
runs the SAME loop with the updates applied inside a shard_map step
against device-resident shard buffers and the probe telescoped over the
mesh in the same compiled program — pair with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for a fake
multi-device CPU run.

Run:  PYTHONPATH=src python examples/dynamic_graph_serving.py
      PYTHONPATH=src python examples/dynamic_graph_serving.py \
          --backend sharded --shards 1
"""
import argparse

import numpy as np

from repro.api import GraphHandle, SimRankSession
from repro.graph import powerlaw_graph


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=("local", "sharded"),
                    default="local")
    ap.add_argument("--shards", type=int, default=None,
                    help="row-partition count for --backend sharded "
                         "(default: local device count)")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    quick = args.backend == "sharded"  # CI runs the mesh loop small
    n_nodes, n_edges = (1_000, 12_000) if quick else (5_000, 60_000)
    src, dst, n = powerlaw_graph(n_nodes, n_edges, seed=0, max_deg=512)
    in_deg = np.bincount(dst, minlength=n)
    handle = GraphHandle.from_edges(
        src, dst, n,
        capacity=len(src) + 10_000,  # headroom for the insert stream
        k_max=int(in_deg.max()) + 64,
    )
    sess = SimRankSession(
        handle, c=0.6, eps_a=0.1, top_k=10,
        batch_q=4, update_batch=64, walk_chunk=256, seed=0,
        backend=args.backend, shards=args.shards,
    )
    print(f"graph n={n} m={len(src)}; n_r={sess.params.n_r} walks/query; "
          f"epoch = {sess.update_batch} update ops + "
          f"{sess.batch_q} queries, one compiled dispatch; "
          f"backend={sess.backend.name}")

    queries = rng.choice(np.where(in_deg > 0)[0], 12)
    for i in range(3):
        # one epoch: a 60-insert burst + a few deletions of original edges
        # + 4 queries, fused into a single compiled dispatch
        sess.queue_update(rng.integers(0, n, 60).astype(np.int32),
                          rng.integers(0, n, 60).astype(np.int32))
        sess.queue_update(src[i * 4:i * 4 + 4], dst[i * 4:i * 4 + 4],
                          insert=False)
        ep = sess.epoch(queries=[int(u) for u in queries[i * 4:(i + 1) * 4]],
                        budget_walks=512)
        print(f"epoch {i}: v{ep.version} "
              f"updates {ep.updates_applied}/{ep.updates_submitted} applied"
              f"{' (overflow->regrown)' if ep.regrown else ''}, "
              f"{len(ep.results)} queries in {ep.latency_s:.2f}s "
              f"(err bound {ep.results[0].error_bound:.3f} @512 walks)")
        for res in ep.results[:2]:
            print(f"  u={res.node} @v{res.version} "
                  f"top3={list(res.topk_nodes[:3])} "
                  f"scores={[round(float(s), 4) for s in res.topk_scores[:3]]}")
    s = sess.stats
    print(f"served {s.queries} queries across {s.epochs} epochs, "
          f"{s.updates} edge updates applied, {s.regrows} regrows — "
          f"zero index rebuilds (index-free)")


if __name__ == "__main__":
    main()
