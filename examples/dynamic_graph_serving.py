"""End-to-end driver (the paper's deployment story): serve batched top-k
SimRank queries on a DYNAMIC graph — edge insertions and deletions are
interleaved with queries and cost O(1), never an index rebuild.

Also demonstrates straggler mitigation (deadline + walk-budget shedding).

Run:  PYTHONPATH=src python examples/dynamic_graph_serving.py
"""
import time

import numpy as np

import jax

from repro.graph import ell_from_edges, graph_from_edges, powerlaw_graph
from repro.serving.engine import SimRankEngine
from repro.serving.straggler import HedgePolicy, dispatch


def main():
    rng = np.random.default_rng(0)
    src, dst, n = powerlaw_graph(5_000, 60_000, seed=0)
    in_deg = np.bincount(dst, minlength=n)
    g = graph_from_edges(src, dst, n, capacity=len(src) + 10_000)
    eg = ell_from_edges(src, dst, n, k_max=int(in_deg.max()) + 64)
    engine = SimRankEngine(g, eg, c=0.6, eps_a=0.1, top_k=10, walk_chunk=256)
    print(f"graph n={n} m={len(src)}; n_r={engine.params.n_r} walks/query")

    queries = rng.choice(np.where(in_deg > 0)[0], 5)
    for i, u in enumerate(queries):
        # dynamic update burst between queries
        b = 64
        t0 = time.time()
        engine.insert(rng.integers(0, n, b).astype(np.int32),
                      rng.integers(0, n, b).astype(np.int32))
        # delete a few of the original edges too
        engine.delete(src[i * 3:i * 3 + 2], dst[i * 3:i * 3 + 2])
        t_upd = time.time() - t0

        res = dispatch(
            engine.run_query, int(u),
            policy=HedgePolicy(deadline_s=120.0, max_retries=1),
            budget=engine.params.n_r,
        )
        print(f"q{i} u={u}: updates({b}+2)={t_upd*1e3:.0f}ms "
              f"query={res.latency_s:.2f}s "
              f"top3={list(res.topk_nodes[:3])} "
              f"scores={[round(float(s),4) for s in res.topk_scores[:3]]}")
    s = engine.stats
    print(f"served {s.queries} queries, {s.updates} edge updates, "
          f"{s.steps} probe steps — zero index rebuilds (index-free)")


if __name__ == "__main__":
    main()
