"""Quickstart: single-source + top-k SimRank with ProbeSim on the paper's
Figure-1 toy graph, validated against the Power Method (Table 2), plus the
fused multi-query serve path (many sources, one compiled step) and a fused
dynamic update->query epoch.

Run:  PYTHONPATH=src python examples/quickstart.py
(The README quickstart snippets are excerpts of this file; CI runs both.)
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    make_params,
    multi_source,
    simrank_power,
    single_source,
    topk,
)
from repro.graph import TOY_TABLE2, ell_from_edges, graph_from_edges, toy_graph
from repro.serving.dynamic_engine import DynamicEngine
from repro.serving.engine import SimRankEngine


def main():
    src, dst, n = toy_graph()
    g = graph_from_edges(src, dst, n)
    eg = ell_from_edges(src, dst, n)

    # the paper's example uses decay c' = 0.25
    params = make_params(n, c=0.25, eps_a=0.05, delta=0.01)
    print(f"ProbeSim params: n_r={params.n_r} walks, l_t={params.max_len}, "
          f"eps={params.eps:.3f} eps_p={params.eps_p:.4f} eps_t={params.eps_t:.3f}")

    key = jax.random.key(0)
    est = np.asarray(single_source(key, g, eg, 0, params, variant="tree"))
    truth = np.asarray(simrank_power(g, c=0.25, iters=60))[0]

    print(f"\n{'node':>5} {'ProbeSim':>9} {'truth':>9} {'Table2':>7}")
    for i, ch in enumerate("abcdefgh"):
        print(f"{ch:>5} {est[i]:9.4f} {truth[i]:9.4f} {TOY_TABLE2[ch]:7.4f}")
    err = np.abs(est - truth)[1:].max()
    print(f"\nmax abs error = {err:.4f}  (guarantee: <= {params.eps_a} "
          f"w.p. >= {1 - params.delta})")
    assert err <= params.eps_a

    nodes, scores = topk(key, g, eg, 0, 3, params, variant="tree")
    print("top-3 similar to 'a':",
          [("abcdefgh"[i], round(float(s), 4)) for i, s in zip(nodes, scores)])

    # --- batched multi-query serving (the fused path) ---------------------
    # Q sources share one compiled step: pooled walk sampling, one SpMM per
    # push level for the whole batch, per-query reduction + top-k fused in.
    us = jnp.array([0, 2, 4])  # a, c, e
    ests = np.asarray(multi_source(key, g, eg, us, params))
    truth_all = np.asarray(simrank_power(g, c=0.25, iters=60))
    for qi, u in enumerate(np.asarray(us)):
        err = np.abs(ests[qi] - truth_all[u])
        err[u] = 0
        print(f"multi_source[{'abcdefgh'[u]}]: max abs error = {err.max():.4f}")
        assert err.max() <= params.eps_a

    # the serving engine drains queued queries through the same fused step
    eng = SimRankEngine(g, eg, c=0.25, eps_a=0.05, top_k=3, batch_q=3, seed=0)
    for u in (0, 2, 4):
        eng.submit(u)
    for res in eng.drain():  # one fused dispatch for the whole batch
        print(f"engine top-3 for '{'abcdefgh'[res.node]}':",
              [("abcdefgh"[i], round(float(s), 4))
               for i, s in zip(res.topk_nodes, res.topk_scores)])

    # --- dynamic epochs: fused update -> query, no index rebuild ----------
    # one jitted epoch step applies a padded edge-update batch to both
    # device mirrors and serves the query batch on the just-updated graph;
    # results carry the graph `version` they were computed against.
    # capacity/k_max reserve headroom for insertions (overflow is flagged
    # and auto-regrown, never silently dropped)
    gd = graph_from_edges(src, dst, n, capacity=len(src) + 8)
    egd = ell_from_edges(src, dst, n, k_max=8)
    deng = DynamicEngine(gd, egd, c=0.25, eps_a=0.05, top_k=3,
                         batch_q=2, update_batch=4, seed=0)
    deng.insert([5, 5], [0, 1])  # f->a, f->b: new 2-step meeting paths
    deng.submit(0)
    deng.submit(2)
    ep = deng.step()  # update + query in ONE compiled dispatch
    print(f"epoch: {ep.updates_applied} updates applied -> graph v{ep.version}")
    for res in ep.results:
        print(f"dynamic top-3 for '{'abcdefgh'[res.node]}' @v{res.version}:",
              [("abcdefgh"[i], round(float(s), 4))
               for i, s in zip(res.topk_nodes, res.topk_scores)])
    assert all(res.version == 1 for res in ep.results)


if __name__ == "__main__":
    main()
