"""Quickstart: the session API on the paper's Figure-1 toy graph.

One ``GraphHandle`` owns both device mirrors; one ``SimRankSession``
serves every query shape (single-source vectors, top-k lists, fused
batches) and every update (immediate or fused update->query epochs).
Estimates are validated against the Power Method (Table 2).

Run:  PYTHONPATH=src python examples/quickstart.py
(The README quickstart snippets are excerpts of this file; CI runs both.)
"""
import numpy as np

from repro.api import GraphHandle, QuerySpec, SimRankSession
from repro.core import simrank_power
from repro.graph import TOY_TABLE2, toy_graph


def main():
    src, dst, n = toy_graph()
    handle = GraphHandle.from_edges(src, dst, n)  # COO push + ELL gather

    # the paper's example uses decay c' = 0.25
    sess = SimRankSession(handle, c=0.25, eps_a=0.05, delta=0.01,
                          top_k=3, batch_q=3, seed=0)
    p = sess.params
    print(f"ProbeSim params: n_r={p.n_r} walks, l_t={p.max_len}, "
          f"eps={p.eps:.3f} eps_p={p.eps_p:.4f} eps_t={p.eps_t:.3f}")

    env = sess.query(QuerySpec(kind="single_source", node=0))
    truth = np.asarray(simrank_power(handle.g, c=0.25, iters=60))[0]

    print(f"\n{'node':>5} {'ProbeSim':>9} {'truth':>9} {'Table2':>7}")
    for i, ch in enumerate("abcdefgh"):
        print(f"{ch:>5} {env.scores[i]:9.4f} {truth[i]:9.4f} "
              f"{TOY_TABLE2[ch]:7.4f}")
    err = np.abs(env.scores - truth)[1:].max()
    print(f"\nmax abs error = {err:.4f}  (envelope bound: "
          f"<= {env.error_bound:.4f} w.p. >= {1 - p.delta}, "
          f"variant={env.variant})")
    assert err <= env.error_bound

    tk = sess.query(QuerySpec(kind="topk", node=0, k=3))
    print("top-3 similar to 'a':",
          [("abcdefgh"[i], round(float(s), 4))
           for i, s in zip(tk.topk_nodes, tk.topk_scores)])

    # --- batched serving (the fused path) ---------------------------------
    # queued specs share one compiled step: pooled walk sampling, one SpMM
    # per push level for the whole batch, per-query reduction + top-k fused
    # in.  PRNG streams are fixed at submit time, so batch composition
    # never changes an answer.
    for u in (0, 2, 4):  # a, c, e
        sess.submit(u)
    for res in sess.drain():  # one fused dispatch for the whole batch
        print(f"fused top-3 for '{'abcdefgh'[res.node]}':",
              [("abcdefgh"[i], round(float(s), 4))
               for i, s in zip(res.topk_nodes, res.topk_scores)])

    # --- dynamic epochs: fused update -> query, no index rebuild ----------
    # one jitted epoch step applies a padded edge-update batch to both
    # device mirrors and serves the query batch on the just-updated graph;
    # results carry the graph `version` they were computed against.
    # capacity/k_max reserve headroom for insertions (overflow is flagged
    # and auto-regrown, never silently dropped)
    hd = GraphHandle.from_edges(src, dst, n, capacity=len(src) + 8, k_max=8)
    dsess = SimRankSession(hd, c=0.25, eps_a=0.05, top_k=3,
                           batch_q=2, update_batch=4, seed=0)
    ep = dsess.epoch(inserts=([5, 5], [0, 1]),  # f->a, f->b: new paths
                     queries=[0, 2])  # update + query, ONE dispatch
    print(f"epoch: {ep.updates_applied} updates applied -> "
          f"graph v{ep.version}")
    for res in ep.results:
        print(f"dynamic top-3 for '{'abcdefgh'[res.node]}' @v{res.version}:",
              [("abcdefgh"[i], round(float(s), 4))
               for i, s in zip(res.topk_nodes, res.topk_scores)])
    assert all(res.version == 1 for res in ep.results)
    print(f"session stats: {dsess.stats}")

    # --- pluggable backends: the same surface, mesh-sharded ---------------
    # backend="sharded" places a dst-partitioned copy of the graph over a
    # local device mesh (shards=N row blocks; 1 here — CPU CI has one
    # device, a real deployment passes shards=N or an explicit mesh=).
    # submit() returns a QueryTicket on every backend: poll()/result()
    # for async consumption, drain() stays the synchronous collect-all.
    ssess = SimRankSession(handle, c=0.25, eps_a=0.05, top_k=3, seed=0,
                           backend="sharded", shards=1)
    ticket = ssess.submit(0)
    env = ticket.result(budget_walks=2048)
    print(f"sharded top-3 for 'a' ({env.variant}):",
          [("abcdefgh"[i], round(float(s), 4))
           for i, s in zip(env.topk_nodes, env.topk_scores)])
    ssess.update(inserts=([5], [0]))  # shard-wise apply, no index rebuild
    assert ssess.version == 1
    # epoch() runs on the mesh too: the update applies inside a shard_map
    # step against device-resident shard buffers, and the probe telescopes
    # in the same compiled program (core/epoch.py)
    ep = ssess.epoch(inserts=([5], [1]), queries=[0], budget_walks=512)
    assert ep.version == 2 and ep.results[0].version == 2
    print(f"mesh epoch: {ep.updates_applied} update + "
          f"{len(ep.results)} query in one compiled dispatch "
          f"({ep.results[0].variant})")

    # --- serving over HTTP: the network front end (DESIGN.md §8) ----------
    # SimRankService cuts concurrent clients' queries into micro-batches
    # (one fused dispatch per cut), bounds admission (429 + Retry-After),
    # and routes X-Tenant headers to per-tenant sessions over ONE shared
    # graph.  start_server binds a stdlib ThreadingHTTPServer over it.
    from repro.serving import (ServiceClient, ServiceConfig, SimRankService,
                               start_server, stop_server)

    svc = SimRankService(handle, config=ServiceConfig(
        batch_window_ms=5.0, default_budget_walks=256))
    server, thread = start_server(svc)  # port=0 picks a free port
    host, port = server.server_address
    client = ServiceClient(host, port, tenant="quickstart")
    reply = client.query(node=0, kind="topk", k=3, seed=7)
    print(f"HTTP top-3 for 'a' (tenant={reply['tenant']}, "
          f"batch_size={reply['batch_size']}):",
          [("abcdefgh"[i], round(s, 4))
           for i, s in zip(reply["topk_nodes"], reply["topk_scores"])])
    rep = client.update(inserts=[(5, 0)])  # serialized; bumps the version
    assert client.healthz()["version"] == rep["version"]
    client.close()
    stop_server(server, thread)  # drains in-flight requests, then closes


if __name__ == "__main__":
    main()
