"""Arch-applicability demo (DESIGN.md §4/§9): ProbeSim as the retrieval
stage for the wide-deep ranker — over a LIVE interaction stream.

SimRank on the user->item bipartite interaction graph is a classic
collaborative-filtering similarity.  ProbeSim computes it index-free, so
the recommender can run on a *sliding window of recent interactions*:
timestamped click events stream in, old interactions age out of the TTL
window as delete batches, and every retrieval query is exact w.r.t. the
current window — no index rebuild between an interaction and the next
recommendation.  The wide-deep model then re-ranks the retrieved
candidates.

Run:  PYTHONPATH=src python examples/simrank_recsys_retrieval.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.api import GraphHandle, QuerySpec, SimRankSession
from repro.configs.base import RecsysConfig
from repro.graph import bipartite_graph
from repro.models.recsys.widedeep import init_widedeep, widedeep_forward
from repro.streams import (
    EventStream,
    FreshnessSLO,
    SessionTransport,
    StreamDriver,
)


def interaction_stream(n_users, n_items, m, horizon, seed=0):
    """Timestamped click events (bipartite arrivals).

    ``bipartite_graph`` emits each interaction as an edge PAIR (u->i then
    i->u, concatenated halves); one click timestamp covers both directions
    so the sliding window stays symmetric as interactions age out.
    """
    src, dst, n = bipartite_graph(n_users, n_items, m, seed=seed)
    half = len(src) // 2
    rng = np.random.default_rng(seed + 1)
    t = np.tile(np.sort(rng.uniform(0.0, horizon, size=half)), 2)
    order = np.argsort(t, kind="stable")  # pair-interleaved, u->i first
    return EventStream(t[order], src[order], dst[order], n), n


def main():
    rng = np.random.default_rng(0)
    n_users, n_items = 1_000, 300
    horizon, ttl = 2.0, 0.8  # seconds of virtual time; TTL recency window
    stream, n = interaction_stream(n_users, n_items, 12_000, horizon)

    # serve the stream: arrivals + TTL expiry in bounded bursts through
    # the session, interleaved with retrieval queries from the live window.
    # k_max is sized for the item-popularity hubs a bipartite click graph
    # grows (auto_regrow would recover from a miss, at recompile cost)
    handle = GraphHandle.from_edges(
        np.empty(0, np.int32), np.empty(0, np.int32), n,
        capacity=1 << 13, k_max=512,
    )
    sess = SimRankSession(handle, c=0.6, eps_a=0.1, delta=0.05, top_k=50,
                          seed=0)
    driver = StreamDriver(
        SessionTransport(sess, mode="epoch"), stream,
        ttl=ttl, tick_s=0.1, queries_per_tick=2, update_burst=256,
        k=20, budget_walks=512,
        slo=FreshnessSLO(staleness_p99_s=2.0),
        checkpoint_every=10, checkpoint_queries=2,
        expert_r=1_000, fresh_budget=2_000,
    )
    rep = driver.run()
    print(
        f"streamed {rep.arrivals} interactions, expired {rep.expired} "
        f"(window={rep.final_live_edges}); {rep.queries} retrievals at "
        f"{rep.qps:.1f} qps, staleness p99 {rep.staleness_p99_s * 1e3:.0f}ms "
        f"(SLO met: {rep.slo_met})"
    )
    for cp in rep.checkpoints:
        print(f"  churn checkpoint t={cp.t:.1f}s: pooled p@20="
              f"{cp.precision_at_k:.2f} over {cp.live_edges} live edges")

    # retrieval: top-k items similar to the currently-hottest item in the
    # window — exact w.r.t. the live window, no index rebuild
    in_deg = np.asarray(sess.backend.handle.eg.in_deg)
    seed_item = n_users + int(np.argmax(in_deg[n_users:]))
    env = sess.query(QuerySpec(kind="topk", node=seed_item, k=50,
                               budget_walks=2_000,
                               key=jax.random.key(0)))
    nodes, scores = env.topk_nodes, env.topk_scores
    item_mask = nodes >= n_users  # keep item nodes only
    cands = nodes[item_mask][:20] - n_users
    print(f"seed item {seed_item - n_users}: retrieved {len(cands)} candidate "
          f"items from the live window, top5={[int(i) for i in cands[:5]]} "
          f"simrank={[round(float(s), 4) for s in scores[item_mask][:5]]}")

    if len(cands) == 0:
        print("no item candidates in the live window; skipping re-rank")
        return

    # ranking: wide-deep scores the retrieved candidates for one user
    cfg = RecsysConfig(name="wd", n_sparse=6, embed_dim=16, mlp=(64, 32),
                       vocab_per_field=max(n_items, 1000), n_dense=4)
    wd = init_widedeep(jax.random.key(1), cfg)
    B = len(cands)
    batch = dict(
        sparse_ids=jnp.asarray(
            np.stack([cands] + [rng.integers(0, 100, B) for _ in range(5)],
                     axis=1).astype(np.int32)
        ),
        dense=jnp.asarray(rng.normal(size=(B, 4)).astype(np.float32)),
    )
    ctr = np.asarray(jax.nn.sigmoid(widedeep_forward(wd, batch, cfg)))
    order = np.argsort(-ctr)
    print("wide-deep re-ranked top5:",
          [(int(cands[i]), round(float(ctr[i]), 3)) for i in order[:5]])


if __name__ == "__main__":
    main()
