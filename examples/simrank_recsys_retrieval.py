"""Arch-applicability demo (DESIGN.md S4): ProbeSim as the retrieval stage
for the wide-deep ranker.

SimRank on the user->item bipartite interaction graph is a classic
collaborative-filtering similarity; ProbeSim computes the top-k similar
items for a seed item index-free (fresh after every interaction), and the
wide-deep model re-ranks the retrieved candidates.

Run:  PYTHONPATH=src python examples/simrank_recsys_retrieval.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.api import GraphHandle, QuerySpec, SimRankSession
from repro.configs.base import RecsysConfig
from repro.graph import bipartite_graph
from repro.models.recsys.widedeep import init_widedeep, widedeep_forward


def main():
    rng = np.random.default_rng(0)
    n_users, n_items = 2_000, 500
    src, dst, n = bipartite_graph(n_users, n_items, 30_000, seed=0)
    handle = GraphHandle.from_edges(src, dst, n)
    in_deg = np.asarray(handle.g.in_deg)

    # retrieval: top-k items similar to a seed item, via ProbeSim (fresh
    # after every interaction — index-free); anytime budget of 2000 walks
    seed_item = n_users + int(np.argmax(in_deg[n_users:]))
    sess = SimRankSession(handle, c=0.6, eps_a=0.1, delta=0.05, top_k=50,
                          seed=0)
    env = sess.query(QuerySpec(kind="topk", node=seed_item, k=50,
                               budget_walks=2000, variant="tree",
                               key=jax.random.key(0)))
    nodes, scores = env.topk_nodes, env.topk_scores
    item_mask = nodes >= n_users  # keep item nodes only
    cands = nodes[item_mask][:20] - n_users
    print(f"seed item {seed_item - n_users}: retrieved {len(cands)} candidate "
          f"items, top5={list(cands[:5])} "
          f"simrank={[round(float(s), 4) for s in scores[item_mask][:5]]}")

    # ranking: wide-deep scores the retrieved candidates for one user
    cfg = RecsysConfig(name="wd", n_sparse=6, embed_dim=16, mlp=(64, 32),
                       vocab_per_field=max(n_items, 1000), n_dense=4)
    wd = init_widedeep(jax.random.key(1), cfg)
    B = len(cands)
    batch = dict(
        sparse_ids=jnp.asarray(
            np.stack([cands] + [rng.integers(0, 100, B) for _ in range(5)],
                     axis=1).astype(np.int32)
        ),
        dense=jnp.asarray(rng.normal(size=(B, 4)).astype(np.float32)),
    )
    ctr = np.asarray(jax.nn.sigmoid(widedeep_forward(wd, batch, cfg)))
    order = np.argsort(-ctr)
    print("wide-deep re-ranked top5:",
          [(int(cands[i]), round(float(ctr[i]), 3)) for i in order[:5]])


if __name__ == "__main__":
    main()
