"""Arch-applicability demo (DESIGN.md S4): ProbeSim as the retrieval stage
for the wide-deep ranker.

SimRank on the user->item bipartite interaction graph is a classic
collaborative-filtering similarity; ProbeSim computes the top-k similar
items for a seed item index-free (fresh after every interaction), and the
wide-deep model re-ranks the retrieved candidates.

Run:  PYTHONPATH=src python examples/simrank_recsys_retrieval.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.core import make_params, topk
from repro.graph import bipartite_graph, ell_from_edges, graph_from_edges
from repro.models.recsys.widedeep import init_widedeep, widedeep_forward


def main():
    rng = np.random.default_rng(0)
    n_users, n_items = 2_000, 500
    src, dst, n = bipartite_graph(n_users, n_items, 30_000, seed=0)
    g = graph_from_edges(src, dst, n)
    in_deg = np.asarray(g.in_deg)
    eg = ell_from_edges(src, dst, n, k_max=int(in_deg.max()) + 1)

    # retrieval: top-k items similar to a seed item, via ProbeSim
    seed_item = n_users + int(np.argmax(in_deg[n_users:]))
    params = make_params(n, c=0.6, eps_a=0.1, delta=0.05,
                         n_r_override=2000)
    nodes, scores = topk(jax.random.key(0), g, eg, seed_item, 50, params,
                         variant="tree")
    nodes, scores = np.asarray(nodes), np.asarray(scores)
    item_mask = nodes >= n_users  # keep item nodes only
    cands = nodes[item_mask][:20] - n_users
    print(f"seed item {seed_item - n_users}: retrieved {len(cands)} candidate "
          f"items, top5={list(cands[:5])} "
          f"simrank={[round(float(s), 4) for s in scores[item_mask][:5]]}")

    # ranking: wide-deep scores the retrieved candidates for one user
    cfg = RecsysConfig(name="wd", n_sparse=6, embed_dim=16, mlp=(64, 32),
                       vocab_per_field=max(n_items, 1000), n_dense=4)
    wd = init_widedeep(jax.random.key(1), cfg)
    B = len(cands)
    batch = dict(
        sparse_ids=jnp.asarray(
            np.stack([cands] + [rng.integers(0, 100, B) for _ in range(5)],
                     axis=1).astype(np.int32)
        ),
        dense=jnp.asarray(rng.normal(size=(B, 4)).astype(np.float32)),
    )
    ctr = np.asarray(jax.nn.sigmoid(widedeep_forward(wd, batch, cfg)))
    order = np.argsort(-ctr)
    print("wide-deep re-ranked top5:",
          [(int(cands[i]), round(float(ctr[i]), 3)) for i in order[:5]])


if __name__ == "__main__":
    main()
